"""Training stack tests: optimizers descend, checkpoint/restart is
bit-exact, error-feedback compression converges, straggler flagging."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import Prefetcher, SyntheticTokens, make_batch_fn
from repro.models.registry import build_model
from repro.runtime import StragglerMonitor, TrainSupervisor
from repro.checkpoint import Checkpointer
from repro.train import make_optimizer, make_train_step, init_train_state
from repro.train.optimizer import cosine_schedule, wsd_schedule
from repro.train import grad_compression as gc


def _tiny_model():
    cfg = get_config("minicpm-2b").smoke().scaled(n_layers=2)
    return cfg, build_model(cfg)


def test_optimizers_descend():
    cfg, model = _tiny_model()
    src = SyntheticTokens(cfg.vocab_size, 16, 4, seed=3)
    batch_fn = make_batch_fn(src)
    for name in ["adamw", "adafactor"]:
        opt = make_optimizer(name, cosine_schedule(1e-2, 5, 200))
        state = init_train_state(model, opt, jax.random.key(0))
        step = jax.jit(make_train_step(model, opt))
        losses = []
        for s in range(20):
            state, metrics = step(state, batch_fn(s % 2))  # 2 repeating batches
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.1, (name, losses[0], losses[-1])
        assert np.all(np.isfinite(losses))


def test_wsd_schedule_shape():
    lr = wsd_schedule(1.0, warmup=10, stable=50, decay=40)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert abs(float(lr(40)) - 1.0) < 1e-6
    assert float(lr(80)) < 1.0
    assert abs(float(lr(100)) - 0.1) < 1e-2


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 3)) * 1.5}}
    ck.save(1, tree, meta={"next_step": 1})
    ck.save(7, tree, meta={"next_step": 7})
    ck.save(9, tree, meta={"next_step": 9})
    assert ck.all_steps() == [7, 9]  # keep=2 gc'd step 1
    got, meta = ck.restore()
    assert meta["next_step"] == 9
    np.testing.assert_array_equal(np.asarray(got["a"]), np.arange(5))
    np.testing.assert_allclose(np.asarray(got["b"]["c"]), 1.5 * np.ones((2, 3)))


def test_restart_bit_exact(tmp_path):
    """Kill training at step 7, restart, resume: final params identical to
    an uninterrupted run (batches are pure functions of the step)."""
    cfg, model = _tiny_model()
    opt = make_optimizer("adamw", cosine_schedule(1e-2, 2, 100))
    src = SyntheticTokens(cfg.vocab_size, 16, 4, seed=5)
    batch_fn = make_batch_fn(src)
    step_fn = jax.jit(make_train_step(model, opt))
    N = 12

    # uninterrupted
    state = init_train_state(model, opt, jax.random.key(1))
    for s in range(N):
        state, _ = step_fn(state, batch_fn(s))
    ref = state["params"]

    # supervised with injected failure at step 7 (after ckpt at step 5)
    boom = {"armed": True}

    def failure_hook(step):
        if step == 7 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated node failure")

    sup = TrainSupervisor(str(tmp_path / "ck"), ckpt_every=5)
    st2 = sup.run(
        init_train_state(model, opt, jax.random.key(1)),
        step_fn,
        batch_fn,
        N,
        failure_hook=failure_hook,
    )
    assert sup.restarts == 1
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(st2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoints restore onto a different mesh (elastic resume)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(3, tree, meta={"next_step": 3})
    mesh = jax.make_mesh((1,), ("data",))
    sh = NamedSharding(mesh, P("data", None))
    got, _ = ck.restore(shardings=sh)
    assert got["w"].sharding == sh
    np.testing.assert_allclose(np.asarray(got["w"]), np.arange(16.0).reshape(4, 4))


def test_grad_compression_quantize_exact_roundtrip():
    x = jax.random.normal(jax.random.key(0), (1000,)) * 3.0
    q, s, r = gc.quantize(x)
    back = gc.dequantize(q, s, x.shape)
    np.testing.assert_allclose(np.asarray(back + r), np.asarray(x), rtol=1e-5, atol=1e-5)
    # quantization error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(r))) <= float(jnp.max(s)) * 0.51


def test_error_feedback_convergence():
    """EF-int8 SGD on a quadratic matches exact SGD to high accuracy."""
    dim = 64
    A = jax.random.normal(jax.random.key(1), (dim, dim)) / np.sqrt(dim)
    H = A @ A.T + 0.1 * jnp.eye(dim)
    b = jax.random.normal(jax.random.key(2), (dim,))

    def grad(x):
        return H @ x - b

    lr = 0.1
    x_exact = jnp.zeros(dim)
    x_comp = jnp.zeros(dim)
    err = jnp.zeros(dim)
    for _ in range(300):
        x_exact = x_exact - lr * grad(x_exact)
        g = grad(x_comp) + err
        q, s, err = gc.quantize(g)
        x_comp = x_comp - lr * gc.dequantize(q, s, g.shape)
    ref = jnp.linalg.solve(H, b)
    # EF-compressed SGD must track exact SGD tightly...
    assert float(jnp.linalg.norm(x_comp - x_exact)) < 1e-3
    # ...and make the same progress toward the optimum
    assert float(jnp.linalg.norm(x_comp - ref)) < float(jnp.linalg.norm(ref)) * 0.5


def test_straggler_monitor():
    m = StragglerMonitor(threshold=2.0, warmup=2)
    flags = [m.record(i, 1.0) for i in range(6)]
    assert not any(flags)
    assert m.record(6, 5.0) is True  # 5x the EWMA
    assert m.record(7, 1.0) is False
    assert m.flagged and m.flagged[0][0] == 6


def test_prefetcher_resumable():
    src = SyntheticTokens(100, 8, 2, seed=9)
    fn = make_batch_fn(src)
    pf = Prefetcher(fn, start_step=5, depth=2)
    s, b = pf.next()
    pf.close()
    assert s == 5
    np.testing.assert_array_equal(b["tokens"], fn(5)["tokens"])


def test_memmap_tokens(tmp_path):
    from repro.data.pipeline import MemmapTokens

    arr = np.arange(1000, dtype=np.int32)
    path = str(tmp_path / "corpus.bin")
    arr.tofile(path)
    ds = MemmapTokens(path, seq_len=16, global_batch=4)
    b0 = ds.batch_at(0)
    b0_again = ds.batch_at(0)
    np.testing.assert_array_equal(b0["tokens"], b0_again["tokens"])
    np.testing.assert_array_equal(b0["labels"][:, :-1], b0["tokens"][:, 1:])
