"""Unit + property tests for the DB-LSH core (paper §III-V)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    C2Index,
    DBLSHParams,
    FBLSH,
    MQIndex,
    alpha_of_gamma,
    brute_force,
    build,
    collision_prob,
    rho_star,
    search_batch,
)
from repro.data import make_clustered, normalize_scale


# ---------------------------------------------------------------------------
# hashing / params theory
# ---------------------------------------------------------------------------


def test_alpha_headline_constant():
    """Lemma 3: alpha = 4.746 at gamma = 2 (w0 = 4 c^2)."""
    assert abs(alpha_of_gamma(2.0) - 4.746) < 2e-3


def test_alpha_monotone_and_threshold():
    """xi is increasing; xi(gamma) > 1 iff gamma > 0.7518 (paper §V-B)."""
    gs = np.linspace(0.2, 4.0, 100)
    vals = [alpha_of_gamma(g) for g in gs]
    assert all(b > a for a, b in zip(vals, vals[1:]))
    assert alpha_of_gamma(0.752) > 1.0 > alpha_of_gamma(0.751)


@given(
    c=st.floats(1.05, 4.0),
    gamma=st.floats(0.8, 3.0),
)
@settings(deadline=None, max_examples=25)
def test_rho_star_bound(c, gamma):
    """Lemma 3: rho* <= 1/c^alpha for w0 = 2 gamma c^2 (log space, since
    rho* underflows float64 for very wide buckets)."""
    import math as _m

    from repro.core.params import log_rho_star

    w0 = 2.0 * gamma * c * c
    alpha = alpha_of_gamma(gamma)
    log_rs = log_rho_star(c, w0)
    assert log_rs <= -alpha * _m.log(c) + 1e-9
    assert log_rs < 0.0  # rho* < 1


def test_collision_prob_monte_carlo():
    """Eq. 4 closed form vs Monte-Carlo simulation of h(o) = a.o."""
    key = jax.random.key(0)
    d, trials = 64, 200_000
    o1 = jnp.zeros((d,))
    for tau, w in [(1.0, 4.0), (2.0, 4.0), (1.0, 9.0), (3.0, 9.0)]:
        o2 = o1.at[0].set(tau)  # distance tau
        a = jax.random.normal(key, (trials, d))
        emp = jnp.mean(jnp.abs(a @ (o1 - o2)) <= w / 2)
        closed = collision_prob(tau, w)
        assert abs(float(emp) - float(closed)) < 5e-3, (tau, w)


def test_observation1_radius_invariance():
    """Observation 1: p(r; w0 r) = p(1; w0) for any r."""
    for r in [0.5, 1.0, 3.0, 17.0]:
        assert abs(
            float(collision_prob(r, 9.0 * r)) - float(collision_prob(1.0, 9.0))
        ) < 1e-6


def test_params_derivation():
    p = DBLSHParams.derive(n=100_000, d=128, c=1.5, t=100, k=50)
    # K = ceil(log_{1/p2}(n/t)), L = ceil((n/t)^rho)
    assert p.K == math.ceil(math.log(p.n / p.t) / math.log(1.0 / p.p2))
    assert p.L == math.ceil((p.n / p.t) ** p.rho)
    assert p.p1 > p.p2
    assert p.budget == 2 * p.t * p.L + p.k
    assert p.cand_per_table >= 2 * p.t + p.k


# ---------------------------------------------------------------------------
# index structure invariants
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_setup():
    key = jax.random.key(7)
    kd, kb = jax.random.split(key)
    # paper §VI-A: queries are drawn from the dataset and removed from it.
    allpts = make_clustered(kd, 4032, 32, n_clusters=16, spread=0.02)
    data, queries = allpts[:4000], allpts[4000:]
    data, queries, _ = normalize_scale(data, queries)
    params = DBLSHParams.derive(n=4000, d=32, c=1.5, t=64, k=10, K=10, L=4)
    index = build(kb, data, params)
    return data, queries, params, index


def test_index_partition(small_setup):
    """Every point id appears exactly once per table; MBRs contain their
    block's points."""
    data, _, params, index = small_setup
    n = data.shape[0]
    ids = np.asarray(index.ids_blocks)  # (L, nb, B)
    for l_ in range(params.L):
        flat = ids[l_].reshape(-1)
        real = flat[flat < n]
        assert sorted(real.tolist()) == list(range(n))
    pb = np.asarray(index.proj_blocks)
    lo = np.asarray(index.mbr_lo)[:, :, None, :]
    hi = np.asarray(index.mbr_hi)[:, :, None, :]
    finite = np.isfinite(pb)
    assert np.all((pb >= lo) | ~finite)
    assert np.all((pb <= hi) | ~finite)


def test_index_projection_consistency(small_setup):
    """proj_blocks really are G_i(o) of the stored ids."""
    data, _, params, index = small_setup
    n = data.shape[0]
    l_ = 0
    ids = np.asarray(index.ids_blocks[l_]).reshape(-1)
    pb = np.asarray(index.proj_blocks[l_]).reshape(-1, params.K)
    A = np.asarray(index.proj_vecs[l_])  # (K, d)
    mask = ids < n
    expect = np.asarray(data)[ids[mask]] @ A.T
    np.testing.assert_allclose(pb[mask], expect, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# query correctness
# ---------------------------------------------------------------------------


def test_search_finds_exact_nn_mostly(small_setup):
    """Theorem 1: success probability >= 1/2 - 1/e ~ 0.13 for c^2-ANN.
    In practice recall is far higher; assert a conservative floor."""
    data, queries, params, index = small_setup
    k = 10
    dists, ids = search_batch(index, queries, k=k, r0=0.5)
    gt_d, gt_i = brute_force(data, queries, k=k)
    recall = np.mean(
        [len(set(np.asarray(a)) & set(np.asarray(b))) / k for a, b in zip(ids, gt_i)]
    )
    assert recall > 0.5, recall
    # returned distances are genuine distances of returned ids
    got = np.asarray(dists)
    for qi in range(queries.shape[0]):
        valid = np.asarray(ids[qi]) < data.shape[0]
        real = np.linalg.norm(
            np.asarray(data)[np.asarray(ids[qi])[valid]] - np.asarray(queries[qi]),
            axis=-1,
        )
        np.testing.assert_allclose(got[qi][valid], real, rtol=1e-3, atol=1e-3)
    # results sorted ascending
    assert np.all(np.diff(got, axis=-1) >= -1e-6)


def test_c2ann_guarantee(small_setup):
    """Every returned 1-NN is a c^2-approximate NN with prob >> 1/2 - 1/e.
    We assert the *aggregate* guarantee: >= 80% of queries satisfy
    ||q,o|| <= c^2 ||q,o*|| (theory floor is 13.2%)."""
    data, queries, params, index = small_setup
    dists, ids = search_batch(index, queries, k=1, r0=0.5)
    gt_d, _ = brute_force(data, queries, k=1)
    ratio = np.asarray(dists[:, 0]) / np.maximum(np.asarray(gt_d[:, 0]), 1e-9)
    frac_ok = np.mean(ratio <= params.c**2 + 1e-3)
    assert frac_ok >= 0.8, (frac_ok, ratio)


def test_rc_nn_semantics(small_setup):
    """(r,c)-NN (Def. 2): when it returns a point at radius r covering the
    true NN, the point's distance must be <= c*r (case 1)."""
    from repro.core import rc_nn

    data, queries, params, index = small_setup
    gt_d, _ = brute_force(data, queries, k=1)
    q = queries[0]
    r_star = float(gt_d[0, 0])
    r = 2.0 * r_star  # true NN well within radius
    d, i = rc_nn(index, q, r=r, k=1)
    # E1 holds w.h.p.: a point should be found, and then it must be valid
    if np.isfinite(np.asarray(d)[0]):
        assert float(d[0]) <= params.c * r * (1 + 1e-3)


@given(seed=st.integers(0, 2**31 - 1))
@settings(deadline=None, max_examples=5)
def test_property_results_are_valid_points(small_setup, seed):
    """Property: any finite returned (dist, id) is consistent — id in range
    and dist equals the true distance."""
    data, _, params, index = small_setup
    q = jax.random.normal(jax.random.key(seed), (data.shape[1],)) * 0.5
    d, i = search_batch(index, q[None, :], k=5, r0=0.5)
    d, i = np.asarray(d)[0], np.asarray(i)[0]
    for dist, idx in zip(d, i):
        if np.isfinite(dist):
            assert 0 <= idx < data.shape[0]
            true = np.linalg.norm(np.asarray(data)[idx] - np.asarray(q))
            assert abs(true - dist) < 1e-2 * max(1.0, true)


# ---------------------------------------------------------------------------
# baselines sanity
# ---------------------------------------------------------------------------


def test_brute_force_is_exact(small_setup):
    data, queries, _, _ = small_setup
    d, i = brute_force(data, queries, k=5)
    dn = np.asarray(data)
    for qi in range(4):
        ref = np.sort(np.linalg.norm(dn - np.asarray(queries[qi]), axis=-1))[:5]
        # rank-1 matmul formulation costs ~1e-3 fp32 ulp vs direct norms
        np.testing.assert_allclose(np.asarray(d[qi]), ref, rtol=2e-3, atol=2e-3)


def test_baselines_reasonable_recall(small_setup):
    data, queries, params, _ = small_setup
    k = 10
    _, gt = brute_force(data, queries, k=k)
    gt = np.asarray(gt)

    mq = MQIndex.build(jax.random.key(1), data, m=15, beta=0.08)
    _, ids = mq.search_batch(queries, k=k)
    rec_mq = np.mean([len(set(a.tolist()) & set(b.tolist())) / k for a, b in zip(np.asarray(ids), gt)])
    assert rec_mq > 0.5, rec_mq

    c2 = C2Index.build(jax.random.key(2), data, m=40, w=2.0)
    _, ids = c2.search_batch(queries, k=k)
    rec_c2 = np.mean([len(set(a.tolist()) & set(b.tolist())) / k for a, b in zip(np.asarray(ids), gt)])
    assert rec_c2 > 0.3, rec_c2

    fb = FBLSH.build(jax.random.key(3), data, K=8, L=4, w0=params.w0, c=1.5, t=32)
    _, ids = fb.search_batch(queries, k=k, r0=0.5)
    rec_fb = np.mean([len(set(a.tolist()) & set(b.tolist())) / k for a, b in zip(np.asarray(ids), gt)])
    assert rec_fb > 0.2, rec_fb


def test_inline_matches_gather_layout(small_setup):
    """'inline' (streaming) and 'gather' layouts return identical results."""
    import dataclasses as dc

    data, queries, params, index = small_setup
    p2 = dc.replace(params, inline_vectors=True)
    index2 = build(jax.random.key(7 + 0), data, p2)  # different key -> rebuild
    # rebuild gather index with same key for apples-to-apples
    kb = jax.random.split(jax.random.key(42), 1)[0]
    ia = build(kb, data, params)
    ib = build(kb, data, p2)
    da, ia_ = search_batch(ia, queries[:8], k=5, r0=0.5)
    db, ib_ = search_batch(ib, queries[:8], k=5, r0=0.5)
    np.testing.assert_allclose(np.asarray(da), np.asarray(db), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ia_), np.asarray(ib_))
