"""Sharded collection lifecycle: the unified mutable protocol.

Tier-1 coverage runs on a 1-shard mesh (CPU hosts expose one device);
the protocol — insert routing, strided stable ids, global-id delete
translation, rebalancing compaction with a gathered id remap, payload
ride-along, snapshot / restore (including the elastic migration path),
version-clock cache invalidation — is identical at any shard count, and
the P=8 routing/balance/migration cases live in
``tests/test_distributed.py::test_sharded_lifecycle_8dev``.

The engine matrix (``REPRO_STORE_TEST_ENGINES``) drives the service
tests: the sharded placement pins per-shard verification to jnp via
``fixed_engine``, so every requested engine must resolve to honest
jnp-labelled tickets.
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.core import DBLSHParams, brute_force
from repro.core.distributed import build_sharded, search_sharded
from repro.store import (
    Collection,
    CompactionPolicy,
    ShardedCollection,
    StoreService,
    open_collection,
    restore_collection,
)
from repro.tune import RecallTarget

ENGINES = os.environ.get(
    "REPRO_STORE_TEST_ENGINES", "jnp"
).replace(",", " ").split()


@pytest.fixture(scope="module")
def setup():
    from repro.data import make_clustered, normalize_scale

    kd, kb = jax.random.split(jax.random.key(29))
    allpts = make_clustered(kd, 1032, 16, n_clusters=8, spread=0.02)
    pts, q, _ = normalize_scale(allpts[:1000], allpts[1000:])
    allpts = np.concatenate([np.asarray(pts), np.asarray(q)])
    data, extra, queries = allpts[:800], allpts[800:1000], allpts[1000:]
    return data, extra, queries, kb


@pytest.fixture()
def mesh():
    return jax.make_mesh((1,), ("data",))


def _make(name, kb, data, mesh, **kw):
    kw.setdefault("policy", CompactionPolicy(auto=False))
    return ShardedCollection.create(
        name, kb, data, mesh, c=1.5, w0=3.6, t=32, k=10, **kw
    )


def _recall(ids, gt_i, k=10):
    return np.mean(
        [len(set(a.tolist()) & set(b.tolist())) / k
         for a, b in zip(np.asarray(ids), np.asarray(gt_i))]
    )


# ---------------------------------------------------------------------------
# Mutations: add / remove / compact against brute force (acceptance
# criterion: results match a fresh index on the post-mutation point set)
# ---------------------------------------------------------------------------


def test_sharded_add_routes_and_keeps_payload(setup, mesh):
    data, extra, queries, kb = setup
    col = _make("sa", kb, data, mesh, payload=np.arange(800))
    assert col.live_count() == 800
    v0 = col.version

    ids = col.add(extra[:50], payload=np.arange(800, 850))
    assert col.live_count() == 850 and col.n == 850
    assert col.version > v0  # mutation bumped the shared clock
    assert col.stats.inserted == 50

    # exact-match query on an inserted point returns its current id + tag
    q = extra[7:8]
    d, i = col.search(q, k=1, r0=0.25, steps=8, exact=True)
    assert float(d[0, 0]) < 1e-3
    assert int(i[0, 0]) == int(ids[7])
    assert int(np.asarray(col.get_payload(i))[0, 0]) == 800 + 7


def test_sharded_remove_never_returned(setup, mesh):
    data, extra, queries, kb = setup
    col = _make("sr", kb, data, mesh)
    _, gt = brute_force(jnp.asarray(data), jnp.asarray(queries), k=5)
    victims = np.unique(np.asarray(gt).reshape(-1))[:40].astype(np.int32)
    col.remove(victims)
    assert col.live_count() == 800 - len(victims)
    assert col.stats.deleted == len(victims)
    d, ids = col.search(queries, k=10, r0=0.5, steps=8)
    fin = np.isfinite(np.asarray(d))
    leaked = set(victims.tolist()) & set(
        np.asarray(ids)[fin].reshape(-1).tolist()
    )
    assert not leaked, leaked


@given(seed=st.integers(0, 10_000))
@settings(deadline=None, max_examples=3)
def test_sharded_update_roundtrip_vs_brute_force(setup, mesh, seed):
    """Property: add -> remove -> compact on a ShardedCollection
    round-trips against a brute-force scan of the surviving point set,
    and (on one shard, where compaction needs no padding) the compacted
    index is *bit-identical* to a fresh sharded build of the survivors
    with the same key — the strongest form of fresh-build parity."""
    data, extra, queries, kb = setup
    rng = np.random.default_rng(seed)
    m = int(rng.integers(16, 96))
    col = _make("sp", kb, data, mesh, payload=np.arange(800))

    ids = col.add(extra[:m], payload=np.arange(800, 800 + m))
    n_tot = 800 + m
    assert col.live_count() == n_tot
    assert ids.dtype == np.int32  # int32 end to end

    n_del = int(rng.integers(10, 120))
    del_ids = rng.choice(n_tot, size=n_del, replace=False).astype(np.int32)
    del_tags = np.asarray(col.get_payload(del_ids[None]))[0].astype(int)
    col.remove(del_ids)
    assert col.live_count() == n_tot - n_del

    # deleted ids can never be returned, even pre-compaction
    d, got = col.search(queries, k=10, r0=0.5, steps=8)
    fin = np.isfinite(np.asarray(d))
    leaked = set(del_ids.tolist()) & set(
        np.asarray(got)[fin].reshape(-1).tolist()
    )
    assert not leaked, leaked

    key_pred = jax.random.split(col._key)[1]  # the key compact will use
    id_map = col.compact()
    n_live = n_tot - n_del
    assert col.n == n_live and col.live_count() == n_live
    assert int((id_map >= 0).sum()) == n_live
    assert np.all(id_map[del_ids] == -1)
    assert np.array_equal(
        np.sort(id_map[id_map >= 0]), np.arange(n_live)
    )

    # payload followed the remap: survivors keep their tags in old-id
    # order (the strided buffer's tail is headroom — zeros, unallocated)
    full = np.concatenate([data, extra[:m]])
    live_mask = np.ones(n_tot, bool)
    live_mask[del_tags] = False  # P=1: tag == original id == global id
    np.testing.assert_array_equal(
        np.asarray(col.payload)[:n_live], np.flatnonzero(live_mask)
    )
    assert np.all(np.asarray(col.payload)[n_live:] == 0)

    # bit-exact fresh-build parity on one shard: same survivors, same
    # key, same id stride (the stride sets the merge sentinel)
    survivors = full[live_mask]
    params = DBLSHParams.derive(
        n=n_live, d=16, c=1.5, w0=3.6, t=32, k=10
    )
    fresh = build_sharded(key_pred, jnp.asarray(survivors), params, mesh,
                          stride=col.sharded.stride)
    d_c, i_c = col.search(queries, k=10, r0=0.5, steps=8)
    d_f, i_f = search_sharded(
        fresh, jnp.asarray(queries), k=10, r0=0.5, steps=8, mesh=mesh
    )
    np.testing.assert_array_equal(np.asarray(i_c), np.asarray(i_f))
    np.testing.assert_array_equal(np.asarray(d_c), np.asarray(d_f))


@given(seed=st.integers(0, 10_000))
@settings(deadline=None, max_examples=3)
def test_sharded_ids_stable_across_adds(setup, mesh, seed):
    """Property (the PR's id contract): ids returned by ``add`` stay
    valid — exact-searchable and removable — across at least three
    subsequent adds, with no remap and no compaction.  The stride
    headroom absorbs the growth, so held ids are durable handles."""
    data, extra, queries, kb = setup
    rng = np.random.default_rng(seed)
    col = _make("stable", kb, data, mesh, payload=np.arange(800))
    assert col.sharded.stride >= 2 * col.sharded.n_local

    held = col.add(extra[:20], payload=np.arange(800, 820))
    held = np.asarray(held).copy()
    off = 20
    for _ in range(3):  # >= 3 subsequent adds
        m = int(rng.integers(8, 40))
        col.add(extra[off:off + m], payload=np.arange(800 + off, 800 + off + m))
        off += m
    assert col.stats.compactions == 0  # no renumbering happened

    # every held id still resolves: exact search returns it verbatim
    probe = rng.choice(20, size=5, replace=False)
    d, i = col.search(extra[probe], k=1, r0=0.25, steps=8, exact=True)
    assert np.all(np.asarray(d)[:, 0] < 1e-3)
    np.testing.assert_array_equal(np.asarray(i)[:, 0], held[probe])
    np.testing.assert_array_equal(
        np.asarray(col.get_payload(held[None]))[0], 800 + np.arange(20)
    )

    # and still removes: the tombstoned handles never return
    col.remove(held)
    d2, i2 = col.search(extra[:20], k=5, r0=0.5, steps=8)
    fin = np.isfinite(np.asarray(d2))
    leaked = set(held.tolist()) & set(
        np.asarray(i2)[fin].reshape(-1).tolist()
    )
    assert not leaked, leaked


def test_sharded_stride_exhaustion_forces_renumber(setup, mesh):
    """An add that would overflow the id stride triggers exactly one
    compact (the sanctioned renumbering event) and then lands in the
    fresh headroom — even with auto-compaction off."""
    data, extra, queries, kb = setup
    col = _make("ovf", kb, data[:40], mesh, payload=np.arange(40))
    stride0 = col.sharded.stride
    assert stride0 == 80  # headroom 2.0 over 40
    ids = col.add(extra[:50], payload=np.arange(40, 90))  # 90 > 80
    assert col.stats.compactions == 1
    assert col.sharded.stride >= 90 and col.live_count() == 90
    # the batch's ids are valid post-renumber handles
    d, i = col.search(extra[3:4], k=1, r0=0.25, steps=8, exact=True)
    assert float(d[0, 0]) < 1e-3 and int(i[0, 0]) == int(ids[3])
    assert int(np.asarray(col.get_payload(i))[0, 0]) == 43


def test_sharded_restore_migrated_rebalances(setup, mesh, tmp_path):
    """The elastic restore path (forced here with ``migrate=True``; a
    genuine P' != P runs in the 8-device script): manifest rows are
    re-partitioned and rebuilt, ids renumber, payload follows its
    points, calibration is dropped as stale."""
    data, extra, queries, kb = setup
    col = _make("el", kb, data, mesh, payload=np.arange(800))
    col.add(extra[:30], payload=np.arange(800, 830))
    col.remove(np.arange(0, 60, 2).astype(np.int32))  # 30 victims
    col.calibrate(queries[:12], k=10)
    step = col.snapshot(str(tmp_path))

    col2 = ShardedCollection.restore(str(tmp_path), mesh=mesh, step=step,
                                     migrate=True)
    assert col2.live_count() == col.live_count() == 800
    assert col2.n == 800  # migration also compacts the tombstones away
    assert col2.calibration is None  # geometry changed: table is stale
    assert col2.version > col.version

    # recall parity vs brute force over the survivors, matched by tag
    # (ids renumbered, the payload is the stable identity)
    full = np.concatenate([data, extra[:30]])
    alive = np.ones(830, bool)
    alive[np.arange(0, 60, 2)] = False
    alive_tags = np.flatnonzero(alive)
    gd, gt = brute_force(jnp.asarray(full[alive_tags]),
                         jnp.asarray(queries), k=10)
    d2, i2 = col2.search(queries, k=10, r0=0.5, steps=8)
    tags2 = np.asarray(col2.get_payload(i2)).astype(int)
    recs = []
    for qi in range(queries.shape[0]):
        f = np.isfinite(np.asarray(d2)[qi])
        want = alive_tags[np.asarray(gt)[qi]]
        recs.append(len(set(tags2[qi][f].tolist()) & set(want.tolist())) / 10)
    assert float(np.mean(recs)) > 0.6, recs

    # migrate=False demands the bit-identical path — and still works on
    # the equal mesh
    col3 = ShardedCollection.restore(str(tmp_path), mesh=mesh, step=step,
                                     migrate=False)
    d3, i3 = col3.search(queries, k=10, r0=0.5, steps=8)
    da, ia = col.search(queries, k=10, r0=0.5, steps=8)
    np.testing.assert_array_equal(np.asarray(i3), np.asarray(ia))
    np.testing.assert_array_equal(np.asarray(d3), np.asarray(da))


def test_get_payload_clamps_both_ends(setup, mesh):
    """A negative id (e.g. -1 from an id map marking a deletion) clamps
    to row 0 instead of wrapping to the buffer tail."""
    data, extra, queries, kb = setup
    col = _make("clamp", kb, data[:100], mesh, payload=np.arange(100) + 7)
    out = np.asarray(col.get_payload(np.array([[-1, -100, 0]])))[0]
    np.testing.assert_array_equal(out, [7, 7, 7])
    # sentinel (id_space) clamps to the last buffer row, as documented
    sent = np.asarray(col.get_payload(np.array([col.id_space])))
    assert sent.shape == (1,)


def test_sharded_auto_compaction_policy_fires(setup, mesh):
    """Growth past the policy ratio triggers compaction through the
    shared lifecycle template, exactly like a local collection."""
    data, extra, queries, kb = setup
    col = _make(
        "sg", kb, data[:100], mesh,
        policy=CompactionPolicy(growth_ratio=1.5, auto=True),
    )
    built0 = col.built_n
    # 150 >= 1.5 * 100 -> compact; the batch also exactly fills the id
    # stride (sized to the growth ratio), so the policy — not a forced
    # stride renumber — is what fires
    col.add(data[100:150])
    assert col.stats.compactions == 1
    assert col.built_n == 150 > built0
    assert col.live_count() == 150
    # hollowness trigger: tombstone most points
    col2 = _make(
        "sh2", kb, data[:200], mesh,
        policy=CompactionPolicy(min_live_ratio=0.5, auto=True),
    )
    col2.remove(np.arange(0, 101))
    assert col2.stats.compactions == 1
    assert col2.live_count() == 99


# ---------------------------------------------------------------------------
# Snapshot / restore (acceptance criterion: fresh version, payload +
# policy + schedule table preserved)
# ---------------------------------------------------------------------------


def test_sharded_snapshot_restore_roundtrip(setup, mesh, tmp_path):
    data, extra, queries, kb = setup
    col = _make(
        "ck", kb, data, mesh, payload=np.arange(800),
        policy=CompactionPolicy(growth_ratio=3.0, auto=False),
        search_policy=RecallTarget(0.9),
    )
    col.add(extra[:30], payload=np.arange(800, 830))
    col.remove(np.arange(5))
    table = col.calibrate(queries[:16], k=10)
    d0, i0 = col.search(queries, k=10, r0=0.5, steps=8)
    step = col.snapshot(str(tmp_path))

    col2 = restore_collection(str(tmp_path), step, mesh=mesh)
    assert isinstance(col2, ShardedCollection)
    assert col2.name == "ck"
    assert col2.version > col.version  # fresh, never aliased
    assert col2.policy == col.policy
    assert col2.search_policy == RecallTarget(0.9)
    assert col2.calibration is not None
    assert col2.calibration.recall == table.recall
    assert col2.calibration.cost_slots == table.cost_slots
    assert (col2.calibration.r0, col2.calibration.k) == (table.r0, table.k)
    assert col2.built_n == col.built_n
    assert col2.live_count() == col.live_count()
    d1, i1 = col2.search(queries, k=10, r0=0.5, steps=8)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i0))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d0))
    np.testing.assert_array_equal(
        np.asarray(col2.payload), np.asarray(col.payload)
    )

    # restored collections keep evolving deterministically: the preserved
    # key makes the next compaction identical across the boundary
    col.compact()
    col2.compact()
    _, i2a = col.search(queries, k=10, r0=0.5, steps=8)
    _, i2b = col2.search(queries, k=10, r0=0.5, steps=8)
    np.testing.assert_array_equal(np.asarray(i2a), np.asarray(i2b))


def test_snapshot_placement_dispatch(setup, mesh, tmp_path):
    """Cross-placement restores fail loudly; restore_collection routes
    from the manifest alone."""
    data, extra, queries, kb = setup
    col = _make("pd", kb, data[:200], mesh)
    step = col.snapshot(str(tmp_path / "sharded"))
    with pytest.raises(ValueError, match="sharded"):
        Collection.restore(str(tmp_path / "sharded"), step)
    with pytest.raises(ValueError, match="mesh"):
        restore_collection(str(tmp_path / "sharded"), step)

    local = Collection.create("pl", kb, data[:200], c=1.5, w0=3.6, t=8, k=5)
    lstep = local.snapshot(str(tmp_path / "local"))
    with pytest.raises(ValueError, match="local"):
        ShardedCollection.restore(
            str(tmp_path / "local"), mesh=mesh, step=lstep
        )
    back = restore_collection(str(tmp_path / "local"), lstep)
    assert isinstance(back, Collection)


# ---------------------------------------------------------------------------
# Auto re-calibration hook (ROADMAP tune item — both placements)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("placement", ["local", "sharded"])
def test_compact_invalidates_and_refits_calibration(setup, mesh, placement):
    data, extra, queries, kb = setup
    if placement == "local":
        col = Collection.create(
            "cal_l", kb, data, c=1.5, w0=3.6, t=32, k=10,
            policy=CompactionPolicy(auto=False),
        )
    else:
        col = _make("cal_s", kb, data, mesh)

    # without retained queries: compact just invalidates
    col.calibrate(queries[:12], k=10)
    assert col.calibration is not None
    col.remove(np.arange(3))
    col.compact()
    assert col.calibration is None

    # with retain=True: compact re-fits automatically from the retained
    # sample (r0 re-derives against the rebuilt geometry)
    t0 = col.calibrate(queries[:12], k=10, retain=True)
    col.remove(np.arange(3))
    col.compact()
    assert col.calibration is not None and col.calibration is not t0
    assert col.calibration.max_steps == t0.max_steps
    # the refitted table plans: a recall target resolves to a schedule
    plan = col.plan(RecallTarget(0.5))
    assert 1 <= plan.steps <= col.calibration.max_steps


# ---------------------------------------------------------------------------
# Service integration: one lifecycle/cache/policy path for both placements
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_sharded_mutations_invalidate_service_cache(setup, mesh, engine):
    """The stale-cache script, sharded: add / remove / compact / restore
    each bump the shared version clock, so repeat queries recompute and
    match a fresh sharded search — never yesterday's index.  The service
    engine default comes from the matrix; fixed_engine pins the honest
    jnp label either way."""
    data, extra, queries, kb = setup
    col = _make("inv", kb, data, mesh, payload=np.arange(800))
    svc = StoreService(
        batch_shapes=(8,), max_wait_ms=1e9, default_k=10, r0=0.5, steps=8,
        engine=engine, interpret=True if engine != "jnp" else None,
        cache_size=256,
    )
    svc.attach(col)
    Q = queries[:8]

    def check_round(expect_cached):
        reqs = [svc.submit("inv", q) for q in Q]
        svc.flush()
        assert all(r.done for r in reqs)
        assert all(r.engine == "jnp" for r in reqs)  # fixed_engine pins
        assert all(r.cached == expect_cached for r in reqs)
        want_d, want_i = col.search(Q, k=10, r0=0.5, steps=8)
        np.testing.assert_array_equal(
            np.stack([r.ids for r in reqs]), np.asarray(want_i)
        )
        np.testing.assert_array_equal(
            np.stack([r.dists for r in reqs]), np.asarray(want_d)
        )
        return reqs

    check_round(False)
    check_round(True)  # warm: identical repeat hits
    col.add(extra[:16], payload=np.arange(800, 816))
    check_round(False)  # add invalidated
    check_round(True)
    col.remove(np.arange(4))
    check_round(False)  # remove invalidated
    col.compact()
    check_round(False)  # compact invalidated
    reqs = check_round(True)
    assert all(r.payload is not None and r.payload.shape == (10,)
               for r in reqs)


def test_sharded_restore_does_not_alias_cache(setup, mesh, tmp_path):
    """Divergent histories from one sharded snapshot must not share
    cache entries (same contract as local restore)."""
    data, extra, queries, kb = setup
    col = _make("al", kb, data[:300], mesh)
    svc = StoreService(
        batch_shapes=(4,), max_wait_ms=1e9, default_k=5, r0=0.5, steps=4,
        cache_size=64,
    )
    svc.attach(col)
    step = col.snapshot(str(tmp_path))
    Q = queries[:4]
    _ = [svc.submit("al", q) for q in Q]
    svc.flush()
    hits0 = svc.cache.hits
    col.add(extra[:16])  # diverge the live collection
    restored = restore_collection(str(tmp_path), step, mesh=mesh)
    svc.collections["al"] = restored
    reqs = [svc.submit("al", q) for q in Q]
    svc.flush()
    assert svc.cache.hits == hits0  # no hit against either old version
    want_d, want_i = restored.search(Q, k=5, r0=0.5, steps=4)
    np.testing.assert_array_equal(
        np.stack([r.ids for r in reqs]), np.asarray(want_i)[:, :5]
    )


# ---------------------------------------------------------------------------
# Router / engine validation (the silent-drop fixes)
# ---------------------------------------------------------------------------


def test_open_collection_forwards_lifecycle_options(setup):
    """``open_collection`` no longer drops policy/search_policy on any
    path (the sharded branch is exercised in the 8-device script — a
    1-device mesh can never fan out)."""
    data, extra, queries, kb = setup
    col = open_collection(
        "opt", kb, data[:200], mesh=None, c=1.5, w0=3.6, t=8, k=5,
        policy=CompactionPolicy(growth_ratio=9.9),
        search_policy=RecallTarget(0.7),
    )
    assert isinstance(col, Collection)
    assert col.policy.growth_ratio == 9.9
    assert col.search_policy == RecallTarget(0.7)


def test_sharded_rejects_unhonorable_engine(setup, mesh):
    data, extra, queries, kb = setup
    with pytest.raises(ValueError, match="jnp engine"):
        _make("bad", kb, data[:200], mesh, engine="kernel")
    col = _make("ok", kb, data[:200], mesh, engine="jnp")
    assert col.default_engine == "jnp" and col.fixed_engine == "jnp"
