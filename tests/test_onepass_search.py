"""Equivalence suite for the one-pass incremental probe pipeline.

The rebuilt ``search_batch_fixed`` selects blocks once at the final
radius, verifies every selected slot once, and replays the radius
schedule as masks over per-slot window halfwidths (DESIGN.md §7).  The
multi-pass seed algorithm is preserved verbatim as
``search_batch_fixed_ref``; this suite pins:

* **new-vs-ref parity** across the engine matrix
  (``REPRO_STORE_TEST_ENGINES``) and ``steps ∈ {1, 4, 8}`` — id-set
  equality and recall parity (distances only to norm-form tolerance);
* **exact bit-equality** — with ``exact=True`` (diff-form distances)
  and an untruncated block budget, the one-pass path returns
  bit-identical distances to the seed path;
* **the nesting contract** (property) — after each step j the
  incremental state equals a from-scratch probe at radius c^j·r0
  (``query.probe_radius`` is the independent oracle);
* **distinct candidate accounting** — the one-pass ``candidates`` stat
  counts every fetched slot once (vs the seed's per-step recount) and
  never counts padded selection slots.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (
    DBLSHParams,
    brute_force,
    build,
    merge_dedup_topk,
    probe_radius,
    search_batch_fixed,
    search_batch_fixed_ref,
)
from repro.data import make_clustered, normalize_scale

ENGINES = os.environ.get(
    "REPRO_STORE_TEST_ENGINES", "jnp kernel inline"
).replace(",", " ").split()

K_TEST = 8


@pytest.fixture(scope="module")
def setup():
    key = jax.random.key(29)
    kd, kb = jax.random.split(key)
    allpts = make_clustered(kd, 2080, 24, n_clusters=12, spread=0.02)
    data, queries = allpts[:2048], allpts[2048:]
    data, queries, _ = normalize_scale(data, queries)
    # max_blocks == nb: the fixed capacity never truncates, so the
    # one-pass and multi-pass paths see identical candidate sets and the
    # equality assertions are exact rather than statistical.
    params = DBLSHParams.derive(
        n=2048, d=24, c=1.5, t=48, k=10, K=8, L=3,
        inline_vectors=True, max_blocks=32,
    )
    index = build(kb, data, params)
    assert params.max_blocks == index.nb
    return np.asarray(data), jnp.asarray(queries), index


def _idsets_equal(d_a, i_a, d_b, i_b):
    d_a, i_a, d_b, i_b = map(np.asarray, (d_a, i_a, d_b, i_b))
    for q in range(d_a.shape[0]):
        fa, fb = np.isfinite(d_a[q]), np.isfinite(d_b[q])
        if set(i_a[q][fa]) != set(i_b[q][fb]):
            return False
    return True


@pytest.mark.parametrize("steps", [1, 4, 8])
@pytest.mark.parametrize("engine", ENGINES)
def test_new_vs_ref_parity(setup, engine, steps):
    """One-pass vs seed: identical id sets, recall parity, distances to
    norm-form tolerance, for every engine and schedule length."""
    data, queries, index = setup
    d_ref, i_ref = search_batch_fixed_ref(
        index, queries, k=K_TEST, r0=0.5, steps=steps, engine="jnp"
    )
    d_new, i_new = search_batch_fixed(
        index, queries, k=K_TEST, r0=0.5, steps=steps, engine=engine,
        interpret=True,
    )
    assert _idsets_equal(d_ref, i_ref, d_new, i_new)
    np.testing.assert_allclose(
        np.asarray(d_new), np.asarray(d_ref), rtol=1e-2, atol=1e-2
    )

    _, gt_i = brute_force(jnp.asarray(data), queries, k=K_TEST)
    rec = lambda ids: np.mean([
        len(set(a.tolist()) & set(b.tolist())) / K_TEST
        for a, b in zip(np.asarray(ids), np.asarray(gt_i))
    ])
    assert abs(rec(i_new) - rec(i_ref)) <= 0.005 + 1e-9


@pytest.mark.parametrize("engine", ENGINES)
def test_exact_bit_equality_to_seed(setup, engine):
    """exact=True restores diff-form distances: bit-equal to the seed
    path (the unit the ISSUE pins for the fp-rounding escape hatch)."""
    data, queries, index = setup
    for steps in (1, 4, 8):
        d_ref, i_ref = search_batch_fixed_ref(
            index, queries, k=K_TEST, r0=0.5, steps=steps, engine="jnp"
        )
        d_new, i_new = search_batch_fixed(
            index, queries, k=K_TEST, r0=0.5, steps=steps, engine=engine,
            interpret=True, exact=True,
        )
        np.testing.assert_array_equal(np.asarray(d_new), np.asarray(d_ref))
        assert _idsets_equal(d_ref, i_ref, d_new, i_new)


@given(steps=st.integers(1, 6), r0_scale=st.integers(2, 8))
@settings(deadline=None, max_examples=6)
def test_nesting_contract_property(setup, steps, r0_scale):
    """Property: incremental per-step results equal from-scratch probes
    at the same radius.

    The oracle rebuilds each step from first principles with
    ``query.probe_radius`` (an independent single-query window probe at
    one width) and the same masked-merge/termination rule; windows nest,
    so replaying deltas over one final-radius selection must land in the
    same state after every step."""
    data, queries, index = setup
    p = index.params
    r0 = r0_scale / 10.0
    n = index.n
    nq = 8
    Q = queries[:nq]
    k = K_TEST

    d_new, i_new = search_batch_fixed(
        index, Q, k=k, r0=r0, steps=steps, exact=True
    )

    # from-scratch oracle: full window probe per (query, step)
    G = jnp.einsum("lkd,qd->qlk", index.proj_vecs, Q)
    best_d = jnp.full((nq, k), jnp.inf)
    best_i = jnp.full((nq, k), n, jnp.int32)
    done = np.zeros((nq,), bool)
    r = jnp.asarray(r0, jnp.float32)
    for _ in range(steps):
        w = p.w0 * r
        d2s, idss = [], []
        for qi in range(nq):
            d2, ids = probe_radius(index, Q[qi], G[qi], w)
            d2s.append(d2)
            idss.append(ids)
        nd, ni = merge_dedup_topk(
            best_d, best_i, jnp.stack(d2s), jnp.stack(idss), n, k
        )
        best_d = jnp.where(jnp.asarray(done)[:, None], best_d, nd)
        best_i = jnp.where(jnp.asarray(done)[:, None], best_i, ni)
        done = done | np.asarray(best_d[:, k - 1] <= jnp.square(p.c * r))
        r = r * p.c

    # ulp-level tolerance: the oracle reduces per query over (M, B, d)
    # while the pipeline reduces the batched (Qn, S, B, d) pool — XLA may
    # re-associate the last-axis sum differently per shape
    np.testing.assert_allclose(
        np.asarray(d_new), np.asarray(jnp.sqrt(best_d)), rtol=0, atol=5e-7
    )
    assert _idsets_equal(d_new, i_new, jnp.sqrt(best_d), best_i)


def test_distinct_candidate_accounting(setup):
    """The rebuilt ``candidates`` stat counts each fetched slot once:
    monotone non-decreasing in steps, equal to the seed count at steps=1,
    and strictly below the seed's per-step recount once windows nest."""
    data, queries, index = setup
    B = index.params.block_size
    prev = None
    for steps in (1, 4, 8):
        *_, s_new = search_batch_fixed(
            index, queries, k=K_TEST, r0=0.5, steps=steps, with_stats=True
        )
        *_, s_ref = search_batch_fixed_ref(
            index, queries, k=K_TEST, r0=0.5, steps=steps, with_stats=True
        )
        c_new = np.asarray(s_new["candidates"])
        c_ref = np.asarray(s_ref["candidates"])
        assert (c_new % B == 0).all()  # whole blocks, no padded slots
        if steps == 1:
            # a single radius has no re-fetch to dedup: counts agree
            np.testing.assert_array_equal(c_new, c_ref)
        else:
            assert (c_new <= c_ref).all()
            assert c_new.sum() < c_ref.sum()
        # distinct slots only grow as the schedule lengthens
        if prev is not None:
            assert (c_new >= prev).all()
        prev = c_new
        np.testing.assert_array_equal(
            np.asarray(s_new["radius_steps"]), np.asarray(s_ref["radius_steps"])
        )


@pytest.mark.parametrize("k", [1, 25])
@pytest.mark.parametrize("exact", [False, True])
@pytest.mark.parametrize("engine", [e for e in ENGINES if e != "jnp"])
def test_fused_vs_ref_k_sweep(setup, engine, k, exact):
    """The fused engines (bins path) vs the seed across k x exact, at
    schedule lengths off the main parity sweep: exact=True is bit-equal
    (the bins decomposition IS the flat merge), norm mode to tolerance."""
    data, queries, index = setup
    for steps in (2, 8):
        d_ref, i_ref = search_batch_fixed_ref(
            index, queries, k=k, r0=0.5, steps=steps, engine="jnp"
        )
        d_new, i_new = search_batch_fixed(
            index, queries, k=k, r0=0.5, steps=steps, engine=engine,
            interpret=True, exact=exact,
        )
        if exact:
            np.testing.assert_array_equal(np.asarray(d_new), np.asarray(d_ref))
        else:
            np.testing.assert_allclose(
                np.asarray(d_new), np.asarray(d_ref), rtol=1e-2, atol=1e-2
            )
        assert _idsets_equal(d_ref, i_ref, d_new, i_new)


@pytest.fixture(scope="module")
def setup_quant(setup):
    """Quantized twins of the fixture index (same data, same LSH key)."""
    data, queries, _ = setup
    out = {}
    for dt in ("bf16", "int8"):
        params = DBLSHParams.derive(
            n=2048, d=24, c=1.5, t=48, k=10, K=8, L=3,
            inline_vectors=True, max_blocks=32, quant_dtype=dt,
        )
        out[dt] = build(jax.random.split(jax.random.key(29))[1],
                        jnp.asarray(data), params)
    return data, queries, out


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
@pytest.mark.parametrize("engine", ENGINES)
def test_quant_recall_band(setup_quant, engine, dtype):
    """Documented tolerance band for the quantized distance path: id-set
    recall vs the fp32 search on the same index >= 0.95 (NOT
    bit-equality — the shortlist is approximate; only a true neighbor
    falling off its bin's 4k shortlist can be lost).  Returned distances
    are exact fp32 (the re-rank), so every returned (id, dist) pair is
    itself exact."""
    data, queries, indexes = setup_quant
    index = indexes[dtype]
    d_fp, i_fp = search_batch_fixed(
        index, queries, k=K_TEST, r0=0.5, steps=8, engine=engine,
        interpret=True,
    )
    d_q, i_q = search_batch_fixed(
        index, queries, k=K_TEST, r0=0.5, steps=8, engine=engine,
        interpret=True, dtype=dtype,
    )
    i_fp, i_q = np.asarray(i_fp), np.asarray(i_q)
    d_fp_n, d_q_n = np.asarray(d_fp), np.asarray(d_q)
    rec = np.mean([
        len(set(i_q[r]) & set(i_fp[r])) / K_TEST for r in range(i_fp.shape[0])
    ])
    assert rec >= 0.95, rec
    # the re-rank contract: every returned distance is the fp32 distance
    # of its id (norm-form re-rank vs this diff-form oracle: rounding
    # only, no quantization error survives the re-rank)
    for r in range(i_q.shape[0]):
        finite = np.isfinite(d_q_n[r])
        ids = i_q[r][finite]
        true = np.sqrt(np.sum(
            (data[ids] - np.asarray(queries)[r][None, :]) ** 2, axis=-1))
        np.testing.assert_allclose(d_q_n[r][finite], true, rtol=1e-3,
                                   atol=1e-3)


def test_quant_termination_stats_match_fp32(setup_quant):
    """C1/C2 accounting runs on fp32 admission counts and exact re-ranked
    distances, so the termination stats of a quantized search match the
    fp32 search on the same index."""
    from repro.core import Termination
    data, queries, indexes = setup_quant
    index = indexes["int8"]
    term = Termination(use_c1=True, use_c2=True)
    *_, s_fp, e_fp = search_batch_fixed(
        index, queries, k=K_TEST, r0=0.5, steps=8, with_explain=True,
        termination=term,
    )
    *_, s_q, e_q = search_batch_fixed(
        index, queries, k=K_TEST, r0=0.5, steps=8, with_explain=True,
        termination=term, dtype="int8",
    )
    np.testing.assert_array_equal(
        np.asarray(s_fp["radius_steps"]), np.asarray(s_q["radius_steps"])
    )
    np.testing.assert_array_equal(
        np.asarray(e_fp["term_cause"]), np.asarray(e_q["term_cause"])
    )


def test_dtype_validation(setup, setup_quant):
    """dtype errors are loud: unknown names, quant+exact (the quantized
    path is a shortlist, not bit-exact), and index/dtype mismatches."""
    data, queries, index = setup
    _, _, indexes = setup_quant
    with pytest.raises(ValueError, match="dtype"):
        search_batch_fixed(index, queries, k=5, dtype="fp64")
    with pytest.raises(ValueError, match="exact"):
        search_batch_fixed(indexes["int8"], queries, k=5, dtype="int8",
                           exact=True)
    with pytest.raises(ValueError, match="quant_dtype"):
        search_batch_fixed(index, queries, k=5, dtype="int8")
    with pytest.raises(ValueError, match="quant_dtype"):
        search_batch_fixed(indexes["bf16"], queries, k=5, dtype="int8")


def test_norm_blocks_invariant(setup):
    """norm_blocks is slot-aligned with ids_blocks: finite slots hold the
    squared norm of their point, padded slots +inf."""
    data, queries, index = setup
    norms = np.sum(np.asarray(data) ** 2, axis=-1)
    nb_arr = np.asarray(index.norm_blocks)
    ids = np.asarray(index.ids_blocks)
    valid = ids < index.n
    np.testing.assert_allclose(
        nb_arr[valid], norms[ids[valid]], rtol=1e-6
    )
    assert np.isinf(nb_arr[~valid]).all()
